"""Out-of-core semantic store — the §4.4 systems claim made real.

The seed repo materialized the whole ``[E, d_l]`` H_sem table in host RAM and
registered it as a fully device-resident frozen buffer. At wikikg2/ATLAS-Wiki
scale (millions of entities x d_l=1024) that is exactly the memory overflow
the paper says the decoupled design avoids. This module replaces it with a
storage/caching layer (DESIGN.md §SemanticStore):

* ``SemanticStoreWriter`` / ``precompute_semantic_table_to_store`` — stream
  the offline PTE encode shard-by-shard onto disk; host memory stays
  O(shard_rows x d_l), never O(E x d_l). Shards are written crash-safely
  (tmp file + fsync + atomic rename, meta.json published last) in either a
  raw fp32 layout or an int8-quantized layout with one fp32 scale per row.
* ``SemanticStore`` — read side: validates shard completeness on open
  (partial/truncated shards are rejected), memory-maps shards lazily and
  serves ``read_rows(ids)`` gathers with on-the-fly dequantization. The OS
  page cache is the only host-side buffer.
* ``SemanticCache`` — a bounded DEVICE-resident hot set of rows fronted by
  an entity-id -> cache-slot indirection (``slot_map``), with CLOCK
  (second-chance) eviction and hit/miss/eviction counters mirroring
  ``core/compile_cache.py``. The train-time gather becomes
  ``sem_cache[sem_slot[ent_ids]]`` instead of ``sem_table[ent_ids]``, so
  device-resident semantic bytes are ``budget_rows x d_l x 4`` + the int32
  indirection — independent of E.

Threading contract (mirrors the PR-1 pipeline): ``plan()`` runs on the
pipeline's scheduler thread while the previous batch executes on device — it
does the store I/O, dequantization and the single host->device put of the
missing rows. ``apply_to()`` runs on the MAIN thread just before the batch
that needs the rows is dispatched: it is one donated in-place scatter.
Because a device executes enqueued programs in order, a stage applied after
step *k*'s dispatch cannot clobber rows step *k* reads, even when eviction
reuses their slots for step *k+1*.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import threading
from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.obs.registry import get_registry
from repro.obs.trace import TRACER

_META = "meta.json"
_VERSION = 1


class SemanticStoreError(RuntimeError):
    """Raised for missing/partial/corrupt stores (crash-safe open contract)."""


def _shard_name(i: int) -> str:
    return f"shard_{i:05d}.bin"


def _shard_nbytes(rows: int, dim: int, quant: str) -> int:
    if quant == "fp32":
        return rows * dim * 4
    if quant == "int8":
        return rows * dim + rows * 4  # int8 data then one fp32 scale per row
    raise SemanticStoreError(f"unknown quant layout {quant!r}")


def quantize_int8(rows: np.ndarray):
    """Per-row symmetric int8: q = round(x / s), s = max|row| / 127.

    Round-trip error is bounded by s/2 = max|row|/254 per element.
    """
    rows = np.asarray(rows, dtype=np.float32)
    scale = np.abs(rows).max(axis=1) / 127.0
    scale = np.where(scale > 0, scale, 1.0).astype(np.float32)
    q = np.clip(np.rint(rows / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale


def dequantize_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale[:, None]


def _write_atomic(path: str, payload: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)  # atomic publish: readers never see partial bytes


class SemanticStoreWriter:
    """Streaming shard writer. ``append`` buffers at most one shard of rows;
    ``finalize`` publishes ``meta.json`` LAST, so a crash at any point leaves
    either a complete store or one ``SemanticStore`` refuses to open."""

    def __init__(self, directory: str, dim: int, quant: str = "fp32",
                 shard_rows: int = 65536):
        if quant not in ("fp32", "int8"):
            raise SemanticStoreError(f"quant must be fp32|int8, got {quant!r}")
        if shard_rows < 1:
            raise SemanticStoreError("shard_rows must be >= 1")
        self.directory = directory
        self.dim = dim
        self.quant = quant
        self.shard_rows = shard_rows
        self._buf: List[np.ndarray] = []
        self._buf_rows = 0
        self._shards: List[Dict] = []
        self._finalized = False
        os.makedirs(directory, exist_ok=True)
        # Rebuilding over an existing store: invalidate it FIRST. Otherwise a
        # crash mid-rebuild leaves the old meta.json pointing at a mix of old
        # and new shard files — same byte counts, so open() would accept it
        # and silently serve mixed rows.
        stale_meta = os.path.join(directory, _META)
        if os.path.exists(stale_meta):
            os.remove(stale_meta)

    def append(self, rows: np.ndarray) -> None:
        assert not self._finalized
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        assert rows.ndim == 2 and rows.shape[1] == self.dim, rows.shape
        self._buf.append(rows)
        self._buf_rows += len(rows)
        while self._buf_rows >= self.shard_rows:
            block = np.concatenate(self._buf, axis=0)
            self._flush_shard(block[: self.shard_rows])
            rest = block[self.shard_rows:]
            self._buf = [rest] if len(rest) else []
            self._buf_rows = len(rest)

    def _flush_shard(self, block: np.ndarray) -> None:
        if self.quant == "fp32":
            payload = np.ascontiguousarray(block, dtype=np.float32).tobytes()
        else:
            q, scale = quantize_int8(block)
            payload = q.tobytes() + scale.tobytes()
        name = _shard_name(len(self._shards))
        _write_atomic(os.path.join(self.directory, name), payload)
        self._shards.append({"file": name, "rows": int(len(block)),
                             "nbytes": len(payload)})

    def finalize(self) -> None:
        if self._buf_rows:
            self._flush_shard(np.concatenate(self._buf, axis=0))
            self._buf, self._buf_rows = [], 0
        meta = {
            "version": _VERSION,
            "n_rows": int(sum(s["rows"] for s in self._shards)),
            "dim": int(self.dim),
            "quant": self.quant,
            "shard_rows": int(self.shard_rows),
            "shards": self._shards,
        }
        _write_atomic(os.path.join(self.directory, _META),
                      json.dumps(meta, indent=1).encode())
        self._finalized = True


class SemanticStore:
    """Read side of the sharded on-disk H_sem. Opening validates the store:
    every shard listed in ``meta.json`` must exist with exactly the expected
    byte count — a crashed/partial write is detected and rejected."""

    def __init__(self, directory: str):
        self.directory = directory
        meta_path = os.path.join(directory, _META)
        if not os.path.isfile(meta_path):
            raise SemanticStoreError(
                f"no semantic store at {directory!r} (missing {_META}; "
                "an interrupted precompute leaves no meta — rebuild)")
        with open(meta_path) as f:
            meta = json.load(f)
        if meta.get("version") != _VERSION:
            raise SemanticStoreError(f"unsupported store version {meta.get('version')}")
        self.n_rows = int(meta["n_rows"])
        self.dim = int(meta["dim"])
        self.quant = str(meta["quant"])
        self.shard_rows = int(meta["shard_rows"])
        self._shards = meta["shards"]
        row_count = 0
        for s in self._shards:
            path = os.path.join(directory, s["file"])
            expect = _shard_nbytes(s["rows"], self.dim, self.quant)
            if expect != s["nbytes"]:
                raise SemanticStoreError(f"inconsistent meta for {s['file']}")
            if not os.path.isfile(path):
                raise SemanticStoreError(f"missing shard {s['file']}")
            actual = os.path.getsize(path)
            if actual != expect:
                raise SemanticStoreError(
                    f"partial shard {s['file']}: {actual} bytes, expected "
                    f"{expect} — store is corrupt/incomplete, rebuild it")
            row_count += s["rows"]
        if row_count != self.n_rows:
            raise SemanticStoreError("meta row count does not match shards")
        self._mmaps: Dict[int, tuple] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ I/O
    def _shard(self, i: int):
        """Lazily mmap shard ``i`` -> (data_view, scale_view_or_None)."""
        with self._lock:
            got = self._mmaps.get(i)
            if got is not None:
                return got
            s = self._shards[i]
            path = os.path.join(self.directory, s["file"])
            rows = s["rows"]
            if self.quant == "fp32":
                mm = np.memmap(path, dtype=np.float32, mode="r",
                               shape=(rows, self.dim))
                got = (mm, None)
            else:
                q = np.memmap(path, dtype=np.int8, mode="r",
                              shape=(rows, self.dim))
                scale = np.memmap(path, dtype=np.float32, mode="r",
                                  offset=rows * self.dim, shape=(rows,))
                got = (q, scale)
            self._mmaps[i] = got
            return got

    def read_rows(self, ids: np.ndarray) -> np.ndarray:
        """Gather rows by entity id -> host fp32 [n, dim] (dequantized)."""
        ids = np.asarray(ids, dtype=np.int64).ravel()
        if len(ids) and (ids.min() < 0 or ids.max() >= self.n_rows):
            raise IndexError(f"ids out of range [0, {self.n_rows})")
        out = np.empty((len(ids), self.dim), dtype=np.float32)
        shard_of = ids // self.shard_rows
        for i in np.unique(shard_of):
            sel = shard_of == i
            local = ids[sel] - i * self.shard_rows
            data, scale = self._shard(int(i))
            if scale is None:
                out[sel] = data[local]
            else:
                out[sel] = dequantize_int8(np.asarray(data[local]),
                                           np.asarray(scale[local]))
        return out

    def iter_shards(self) -> Iterable[tuple]:
        """Yield (row_lo, fp32 rows) per shard — the bulk-export scan (e.g.
        materializing a full-resident table on a small graph, or migrating a
        store between quant layouts) with at most one shard in memory."""
        lo = 0
        for i, s in enumerate(self._shards):
            data, scale = self._shard(i)
            rows = (np.asarray(data, dtype=np.float32) if scale is None
                    else dequantize_int8(np.asarray(data), np.asarray(scale)))
            yield lo, rows
            lo += s["rows"]

    @property
    def disk_nbytes(self) -> int:
        return sum(s["nbytes"] for s in self._shards)

    # ------------------------------------------------------------ live append
    def append_rows(self, rows: np.ndarray) -> range:
        """Crash-safe in-place append for live entity writes (DESIGN.md
        §LiveStore). Returns the id range of the new rows.

        The ``read_rows`` gather assumes UNIFORM geometry — every shard
        except the last holds exactly ``shard_rows`` rows — so an append
        first tops up the partial last shard, then emits fresh full/partial
        shards. The same shard-writer idiom keeps every state openable:

        * each payload goes through ``_write_atomic`` (tmp + fsync + atomic
          rename), so no file is ever partially visible;
        * the topped-up last shard is written under a NEW revision-suffixed
          name (``shard_NNNNN.rK.bin``) — rewriting the old file in place
          would make a crash-between-file-and-meta unopenable (size no
          longer matches the old meta);
        * ``meta.json`` is published LAST: a crash before it leaves the old
          meta pointing at untouched old files (old store opens cleanly); a
          crash after it leaves the new state fully on disk.

        Existing rows keep their EXACT stored bytes: the int8 merge
        concatenates the old quantized payload with newly quantized rows
        (old q + new q, old scales + new scales) — never dequantize/
        requantize, so pre-append reads stay bit-identical post-append."""
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        if rows.ndim != 2 or rows.shape[1] != self.dim:
            raise SemanticStoreError(
                f"append rows shape {rows.shape} != (n, {self.dim})")
        if len(rows) == 0:
            return range(self.n_rows, self.n_rows)
        with self._lock:
            shards = [dict(s) for s in self._shards]
            superseded: List[str] = []
            merged_idx = None
            pos = 0
            if shards and shards[-1]["rows"] < self.shard_rows:
                last = shards[-1]
                merged_idx = len(shards) - 1
                take = min(self.shard_rows - last["rows"], len(rows))
                block = rows[:take]
                pos = take
                old_path = os.path.join(self.directory, last["file"])
                with open(old_path, "rb") as f:
                    raw = f.read()
                if len(raw) != last["nbytes"]:
                    raise SemanticStoreError(
                        f"shard {last['file']} changed size on disk")
                if self.quant == "fp32":
                    payload = raw + block.tobytes()
                else:
                    q, scale = quantize_int8(block)
                    split = last["rows"] * self.dim
                    payload = (raw[:split] + q.tobytes()
                               + raw[split:] + scale.tobytes())
                stem, rev = last["file"][: -len(".bin")], 0
                if ".r" in stem:
                    stem, _, r = stem.rpartition(".r")
                    rev = int(r)
                name = f"{stem}.r{rev + 1}.bin"
                _write_atomic(os.path.join(self.directory, name), payload)
                superseded.append(last["file"])
                shards[-1] = {"file": name, "rows": last["rows"] + take,
                              "nbytes": len(payload)}
            while pos < len(rows):
                block = rows[pos: pos + self.shard_rows]
                pos += len(block)
                if self.quant == "fp32":
                    payload = block.tobytes()
                else:
                    q, scale = quantize_int8(block)
                    payload = q.tobytes() + scale.tobytes()
                name = _shard_name(len(shards))
                _write_atomic(os.path.join(self.directory, name), payload)
                shards.append({"file": name, "rows": int(len(block)),
                               "nbytes": len(payload)})
            new_n = self.n_rows + len(rows)
            meta = {
                "version": _VERSION,
                "n_rows": int(new_n),
                "dim": int(self.dim),
                "quant": self.quant,
                "shard_rows": int(self.shard_rows),
                "shards": shards,
            }
            _write_atomic(os.path.join(self.directory, _META),
                          json.dumps(meta, indent=1).encode())
            # Publish point passed — swap in-memory state and retire the
            # superseded mmap/file (best effort: a reader elsewhere may
            # still hold the old mapping; the unlink only drops the name).
            old_n = self.n_rows
            self.n_rows = new_n
            self._shards = shards
            if merged_idx is not None:
                self._mmaps.pop(merged_idx, None)
            for f in superseded:
                try:
                    os.remove(os.path.join(self.directory, f))
                except OSError:
                    pass
            return range(old_n, new_n)


# --------------------------------------------------------------------------
# Streaming offline precompute (Eq. 10) — never holds [E, d_l] in host RAM.
# --------------------------------------------------------------------------

def precompute_semantic_table_to_store(
    kg,
    directory: str,
    pte=None,
    batch_size: int = 256,
    unload: bool = True,
    smooth: float = 0.5,
    quant: str = "fp32",
    shard_rows: int = 65536,
) -> SemanticStore:
    """Streaming twin of ``semantic/pte.py::precompute_semantic_table``:
    encodes shard-by-shard to disk and (in fp32 mode) produces BIT-IDENTICAL
    rows to the in-memory version — same encode batch boundaries, same
    per-row neighbor-smoothing accumulation order, same dtypes.

    Host memory is O(shard_rows x d_l): pass 1 streams normalized encodings
    into an on-disk staging memmap; pass 2 computes one output shard at a
    time, gathering the neighbor rows it needs from the staging file (the
    mmap fancy-index touches only those pages)."""
    from repro.semantic.pte import StubPTE, encode_normalized_batches

    pte = pte or StubPTE()
    E = kg.n_entities
    dim = pte.cfg.d_l
    writer = SemanticStoreWriter(directory, dim, quant=quant,
                                 shard_rows=shard_rows)

    stage_path = os.path.join(directory, "stage1.tmp")
    stage = np.memmap(stage_path, dtype=np.float32, mode="w+", shape=(E, dim))
    try:
        lo = 0
        for block in encode_normalized_batches(kg, pte, batch_size):
            stage[lo: lo + len(block)] = block
            lo += len(block)
        stage.flush()

        if smooth > 0:
            heads = kg.triples[:, 0]
            tails = kg.triples[:, 2]
            cnt = np.ones((E, 1))  # float64, matching the in-memory version
            np.add.at(cnt, heads, 1.0)
            np.add.at(cnt, tails, 1.0)
            for slo in range(0, E, shard_rows):
                shi = min(slo + shard_rows, E)
                nb = np.zeros((shi - slo, dim), dtype=np.float32)
                mask = (heads >= slo) & (heads < shi)
                np.add.at(nb, heads[mask] - slo, stage[tails[mask]])
                mask = (tails >= slo) & (tails < shi)
                np.add.at(nb, tails[mask] - slo, stage[heads[mask]])
                block = stage[slo:shi] + smooth * nb / cnt[slo:shi]
                block /= np.linalg.norm(block, axis=1, keepdims=True) + 1e-6
                writer.append(block.astype(np.float32))
        else:
            for slo in range(0, E, shard_rows):
                writer.append(np.asarray(stage[slo: min(slo + shard_rows, E)]))
        writer.finalize()
    finally:
        del stage
        if os.path.exists(stage_path):
            os.remove(stage_path)
    if unload:
        pte.unload()
    return SemanticStore(directory)


# --------------------------------------------------------------------------
# Device-resident hot-set cache
# --------------------------------------------------------------------------

class _ArrayReader:
    """Adapter so tests/benchmarks can back a cache by an in-memory table."""

    def __init__(self, table: np.ndarray):
        self._t = np.asarray(table, dtype=np.float32)
        self.n_rows, self.dim = self._t.shape

    def read_rows(self, ids: np.ndarray) -> np.ndarray:
        return self._t[np.asarray(ids, dtype=np.int64).ravel()]


@dataclasses.dataclass
class SemStage:
    """One planned staging op: scatter ``rows`` into cache ``slots`` and
    point ``ids`` at them. Arrays are already device-resident (the single
    host->device put happened in ``plan``, off the critical path)."""

    seq: int
    slots: object       # device int32 [m_padded]
    ids: object         # device int32 [m_padded]
    rows: object        # device fp32 [m_padded, dim]
    n_rows: int         # real staged rows (before pow2 padding)
    background: bool


def _jit_apply():
    import jax

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def apply(buffer, slot_map, slots, ids, rows):
        return buffer.at[slots].set(rows), slot_map.at[ids].set(slots)

    return apply


_APPLY = None


def _apply_stage(buffer, slot_map, slots, ids, rows):
    global _APPLY
    if _APPLY is None:
        _APPLY = _jit_apply()
    return _APPLY(buffer, slot_map, slots, ids, rows)


class SemanticCache:
    """Bounded device-resident hot set of H_sem rows + id->slot indirection.

    The device state is a pair of buffers registered as FROZEN params
    (``models/base.py::init_params``): ``sem_cache`` [budget_rows, d_l] fp32
    and ``sem_slot`` [n_rows] int32. Host-side metadata (which entity owns
    which slot, CLOCK ref bits) lives here and is only ever mutated under a
    lock inside ``plan`` — one planner at a time (the pipeline's scheduler
    thread, or the trainer itself in sync mode).

    Eviction is CLOCK (second-chance): hits set a ref bit; the sweep hand
    clears ref bits until it finds a cold slot. Slots holding rows of the
    batch being planned are pinned so a batch can never evict its own rows;
    rows of an IN-FLIGHT previous batch may be chosen, which is safe because
    the scatter is enqueued after that batch's program (in-order device
    stream, see module docstring).

    ``plan`` -> ``apply_to`` form an ordered handshake (``seq``): stages must
    be applied in plan order. If a pipeline shuts down with planned-but-
    unapplied stages (queue drained on close), call ``reconcile()`` — it
    resets the metadata so the next plan restages from the store.
    """

    def __init__(self, store, budget_rows: int, n_rows: Optional[int] = None,
                 name: str = "sem_cache", ctx=None):
        import jax.numpy as jnp

        if isinstance(store, np.ndarray):
            store = _ArrayReader(store)
        if budget_rows < 1:
            raise ValueError("budget_rows must be >= 1")
        self.store = store
        self.budget_rows = int(budget_rows)
        self.n_rows = int(n_rows if n_rows is not None else store.n_rows)
        self.dim = int(store.dim)
        self.name = name
        # Placement context: under a mesh the cache buffers (and every staged
        # row batch) are REPLICATED across the mesh — the budget bounds them,
        # and replication keeps the plan/apply scatter collective-free (the
        # sharding rule tables pin sem_cache/sem_slot replicated to match).
        self._ctx = ctx
        self._sharded = ctx is not None and getattr(ctx, "is_sharded", False)
        # Device state (handed to init_params; thereafter threaded through
        # the donated params dict — the cache never reuses these handles).
        self.buffer = jnp.zeros((self.budget_rows, self.dim), dtype=jnp.float32)
        self.slot_map = jnp.zeros((self.n_rows,), dtype=jnp.int32)
        if self._sharded:
            self.buffer = ctx.put_replicated(self.buffer)
            self.slot_map = ctx.put_replicated(self.slot_map)
        # Host metadata (source of truth for residency).
        self._slot_of = np.full(self.n_rows, -1, dtype=np.int32)
        self._owner = np.full(self.budget_rows, -1, dtype=np.int64)
        self._ref = np.zeros(self.budget_rows, dtype=bool)
        self._hand = 0
        self._lock = threading.Lock()
        self._planned_seq = 0
        self._applied_seq = 0
        # Counters: registry metrics (compile_cache.py idiom, DESIGN.md
        # §Observability) — still int-comparable attributes.
        self._metrics = get_registry().group("sem_cache", cache=name)
        self.hits = self._metrics.counter("hits")
        self.misses = self._metrics.counter("misses")
        self.evictions = self._metrics.counter("evictions")
        self.stages = self._metrics.counter("stages")
        self.stages_background = self._metrics.counter("stages_background")
        self.rows_staged = self._metrics.counter("rows_staged")
        self.bytes_staged = self._metrics.counter("bytes_staged")
        self.resident_gauge = self._metrics.gauge("resident_rows")

    # ------------------------------------------------------------- planning
    def plan(self, ent_ids, background: bool = False) -> Optional[SemStage]:
        """Ensure every id in ``ent_ids`` will be device-resident once the
        returned stage is applied. Runs store reads + dequantize + the single
        device put here (scheduler thread); returns None on a full hit."""
        import jax.numpy as jnp

        with self._lock:
            ids = np.unique(np.asarray(ent_ids, dtype=np.int64).ravel())
            if len(ids) and (ids[0] < 0 or ids[-1] >= self.n_rows):
                raise IndexError(f"entity ids out of range [0, {self.n_rows})")
            if len(ids) > self.budget_rows:
                raise RuntimeError(
                    f"batch needs {len(ids)} semantic rows but the cache "
                    f"budget is {self.budget_rows}; raise "
                    f"--semantic-budget-rows or shrink the batch")
            known = self._slot_of[ids]
            hit = known >= 0
            self.hits += int(hit.sum())
            self._ref[known[hit]] = True
            missing = ids[~hit]
            self.misses += len(missing)
            if len(missing) == 0:
                return None
            pinned = np.zeros(self.budget_rows, dtype=bool)
            pinned[known[hit]] = True
            slots = np.empty(len(missing), dtype=np.int32)
            for j, e in enumerate(missing):
                while True:  # CLOCK sweep; terminates: unpinned >= remaining
                    s = self._hand
                    self._hand = (self._hand + 1) % self.budget_rows
                    if pinned[s]:
                        continue
                    if self._ref[s]:
                        self._ref[s] = False
                        continue
                    break
                old = self._owner[s]
                if old >= 0:
                    self._slot_of[old] = -1
                    self.evictions += 1
                self._owner[s] = e
                self._slot_of[e] = s
                self._ref[s] = True
                pinned[s] = True
                slots[j] = s
            m = len(missing)
            self.stages += 1
            if background:
                self.stages_background += 1
            self.rows_staged += m
            self.bytes_staged += m * self.dim * 4
            self.resident_gauge.set(int((self._owner >= 0).sum()))
            self._planned_seq += 1
            seq = self._planned_seq
        # Store I/O, dequantize and the device put happen OUTSIDE the lock:
        # the main thread's apply_to (which takes the lock for its seq check)
        # must never wait out a disk read, or the pre-dispatch apply would
        # reintroduce exactly the mid-step stall this cache eliminates. The
        # metadata above is already consistent — a subsequent plan builds on
        # it regardless of when these rows land.
        with TRACER.span("store_io", rows=m):
            rows = self.store.read_rows(missing)  # host gather + dequantize
        # Pad to a power of two so the apply scatter has a bounded signature
        # set (edge-repeat: duplicate slots write the same row).
        mp = 1 << int(np.ceil(np.log2(max(m, 1))))
        if mp > m:
            slots = np.concatenate([slots, np.full(mp - m, slots[-1], np.int32)])
            missing = np.concatenate([missing, np.full(mp - m, missing[-1])])
            rows = np.concatenate([rows, np.repeat(rows[-1:], mp - m, axis=0)])
        # Under a mesh context, stage replicated onto the mesh so the donated
        # scatter matches the (replicated) cache buffers — still one logical
        # host->device transfer either way.
        put = self._ctx.put_replicated if self._sharded else jnp.asarray
        return SemStage(
            seq=seq,
            slots=put(slots.astype(np.int32)),
            ids=put(missing.astype(np.int32)),
            rows=put(rows),  # the single device put
            n_rows=m,
            background=background,
        )

    # -------------------------------------------------------------- applying
    def apply_to(self, params: Dict, stage: SemStage) -> Dict:
        """Main-thread half of the handshake: one donated in-place scatter
        into the cache buffers threaded through ``params``."""
        with self._lock:
            if stage.seq != self._applied_seq + 1:
                raise RuntimeError(
                    f"stage applied out of order (got seq {stage.seq}, "
                    f"expected {self._applied_seq + 1})")
            self._applied_seq = stage.seq
        buffer, slot_map = _apply_stage(params["sem_cache"], params["sem_slot"],
                                        stage.slots, stage.ids, stage.rows)
        return {**params, "sem_cache": buffer, "sem_slot": slot_map}

    def reconcile(self) -> None:
        """Call after tearing down a pipeline: if any planned stage was never
        applied (drained queue), device state no longer matches the metadata
        — drop all residency so future plans restage from the store."""
        with self._lock:
            if self._planned_seq != self._applied_seq:
                self._reset_locked()

    def reset(self) -> None:
        with self._lock:
            self._reset_locked()

    def _reset_locked(self) -> None:
        self._slot_of[:] = -1
        self._owner[:] = -1
        self._ref[:] = False
        self._hand = 0
        self._planned_seq = self._applied_seq = 0
        self.resident_gauge.set(0)

    # -------------------------------------------------------------- metrics
    @property
    def resident_rows(self) -> int:
        return int((self._owner >= 0).sum())

    @property
    def hit_rate(self) -> float:
        n = int(self.hits) + int(self.misses)
        return int(self.hits) / n if n else 0.0

    @property
    def prefetch_overlap_frac(self) -> float:
        n = int(self.stages)
        return int(self.stages_background) / n if n else 0.0

    @property
    def device_resident_sem_bytes(self) -> int:
        """Peak device bytes pinned by the semantic subsystem: the hot-set
        buffer + the id->slot indirection (independent of E x d_l)."""
        return self.budget_rows * self.dim * 4 + self.n_rows * 4

    def resident_ids(self) -> np.ndarray:
        return np.sort(self._owner[self._owner >= 0])

    def stats(self) -> Dict[str, float]:
        return {
            "name": self.name,
            "budget_rows": self.budget_rows,
            "resident_rows": self.resident_rows,
            "hits": int(self.hits),
            "misses": int(self.misses),
            "evictions": int(self.evictions),
            "hit_rate": self.hit_rate,
            "stages": int(self.stages),
            "stages_background": int(self.stages_background),
            "sync_stages": int(self.stages) - int(self.stages_background),
            "prefetch_overlap_frac": self.prefetch_overlap_frac,
            "rows_staged": int(self.rows_staged),
            "bytes_staged": int(self.bytes_staged),
            "device_resident_sem_bytes": self.device_resident_sem_bytes,
        }

    def reset_counters(self) -> None:
        """Zero counters (not residency) — e.g. after benchmark warmup."""
        with self._lock:
            self._metrics.reset()
            self.resident_gauge.set(int((self._owner >= 0).sum()))
