from repro.semantic.pte import PTEConfig, StubPTE, precompute_semantic_table

__all__ = ["PTEConfig", "StubPTE", "precompute_semantic_table"]
