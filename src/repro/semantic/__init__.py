from repro.semantic.pte import (PTEConfig, StubPTE, encode_normalized_batches,
                                precompute_semantic_table)
from repro.semantic.store import (SemanticCache, SemanticStore,
                                  SemanticStoreError, SemanticStoreWriter,
                                  SemStage, dequantize_int8,
                                  precompute_semantic_table_to_store,
                                  quantize_int8)

__all__ = [
    "PTEConfig",
    "StubPTE",
    "encode_normalized_batches",
    "precompute_semantic_table",
    "SemanticCache",
    "SemanticStore",
    "SemanticStoreError",
    "SemanticStoreWriter",
    "SemStage",
    "quantize_int8",
    "dequantize_int8",
    "precompute_semantic_table_to_store",
]
