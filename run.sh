#!/usr/bin/env bash
# Tuned launcher (DESIGN.md §Autotuner, launch-environment half).
#
# Shell-native equivalent of `python -m repro.launch.env -- ...` for the
# common case:
#
#   ./run.sh -m repro.launch.train --dataset FB15k --model gqe ...
#   ./run.sh benchmarks/run.py --only autotune
#
# Everything here is additive: variables you already exported win.
set -euo pipefail
cd "$(dirname "$0")"

# tcmalloc: arena-contention-free allocator for the pipeline's host threads.
# LD_PRELOAD only applies at process start, which is why this is a launcher.
if [ -z "${LD_PRELOAD:-}" ]; then
  for lib in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
             /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
             /usr/lib/libtcmalloc.so.4 \
             /usr/lib/libtcmalloc_minimal.so.4; do
    if [ -f "$lib" ]; then
      export LD_PRELOAD="$lib"
      break
    fi
  done
fi
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"

# Quiet the TF/XLA C++ banner; put step markers at the fused train-step
# boundary (where the profiler + obs span bridge expect them).
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"
if [[ "${XLA_FLAGS:-}" != *"--xla_step_marker_location"* ]]; then
  export XLA_FLAGS="${XLA_FLAGS:-} --xla_step_marker_location=1"
fi

# fp32 bit-identity contracts: never let x64 defaults sneak in.
export JAX_ENABLE_X64="${JAX_ENABLE_X64:-0}"
export JAX_DEFAULT_DTYPE_BITS="${JAX_DEFAULT_DTYPE_BITS:-32}"

# Persisted kernel-tile autotune cache (tuning cost paid once per machine).
export REPRO_AUTOTUNE_CACHE="${REPRO_AUTOTUNE_CACHE:-$PWD/.autotune_cache.json}"

export PYTHONPATH="$PWD/src${PYTHONPATH:+:$PYTHONPATH}"

exec python "$@"
